"""Shared benchmark utilities: timing, data, CSV output, runtime plans."""
from __future__ import annotations

import time

import jax
import numpy as np


def batched_plan(spec, n: int, nq: int, nr: int,
                 engine_name: str = "wavefront", with_traceback=None):
    """Batched CompiledPlan from the shared runtime cache.

    All suites compile through ``repro.runtime`` so a shape measured here
    is the same executable api/batch/serve would dispatch.
    """
    from repro.runtime import plan as plan_mod
    if with_traceback is None:
        with_traceback = spec.traceback is not None
    char = spec.char_shape
    return plan_mod.get_plan(spec, engine_name, (nq,) + char, (nr,) + char,
                             batch_size=n, with_traceback=with_traceback)


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call of a jitted fn (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def kernel_batch(rng, spec, n: int, nq: int, nr: int):
    """Batch of random inputs matching a kernel spec's alphabet."""
    import jax.numpy as jnp
    if spec.char_shape == (5,):
        from repro.core.kernels_zoo.profile import make_profile
        qs = np.stack([make_profile(rng, nq) for _ in range(n)])
        rs = np.stack([make_profile(rng, nr) for _ in range(n)])
    elif spec.char_shape == (2,):
        qs = rng.normal(size=(n, nq, 2)).astype(np.float32)
        rs = rng.normal(size=(n, nr, 2)).astype(np.float32)
    elif spec.char_dtype == jnp.int32:
        qs = rng.integers(0, 128, (n, nq)).astype(np.int32)
        rs = rng.integers(0, 128, (n, nr)).astype(np.int32)
    else:
        hi = 20 if spec.name == "protein_local" else 4
        qs = rng.integers(0, hi, (n, nq)).astype(np.uint8)
        rs = rng.integers(0, hi, (n, nr)).astype(np.uint8)
    ql = np.full((n,), nq, np.int32)
    rl = np.full((n,), nr, np.int32)
    return (jnp.asarray(qs), jnp.asarray(rs), jnp.asarray(ql),
            jnp.asarray(rl))
