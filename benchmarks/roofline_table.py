"""§Roofline table generator: dryrun JSONL -> markdown rows.

Per (arch x shape x mesh): the three roofline terms (seconds), the
dominant term, MODEL_FLOPS/HLO_FLOPs useful-work ratio, and the roofline
fraction = useful compute time / bound term (what the hillclimb maximizes).
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict

from repro import configs
from repro.launch import roofline as R


def load(path: str):
    recs = []
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    # keep the last record per cell (later runs supersede)
    dedup = {}
    for r in recs:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return list(dedup.values())


def rows(path: str, mesh: str = "16x16"):
    out = []
    for rec in load(path):
        if rec["mesh"] != mesh:
            continue
        cfg = configs.get(rec["arch"])
        shape = configs.SHAPES[rec["shape"]]
        if rec["status"] == "skipped":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "skipped": rec["reason"]})
            continue
        if rec["status"] != "ok":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "error": rec.get("error", "?")})
            continue
        rf = R.from_record(rec, cfg, shape)
        n_dev = 512 if mesh == "2x16x16" else 256
        useful_s = rf.model_flops / n_dev / R.PEAK_FLOPS
        out.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "compute_s": rf.compute_s, "memory_s": rf.memory_s,
            "collective_s": rf.collective_s, "dominant": rf.dominant,
            "useful_ratio": rf.useful_ratio,
            "roofline_frac": useful_s / rf.bound_s if rf.bound_s else 0.0,
            "peak_gib": rec["memory"]["peak_per_device"] / 2 ** 30,
        })
    return out


def markdown(path: str, mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | compute s | memory s | coll s | dominant | "
        "useful ratio | roofline frac | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows(path, mesh), key=lambda x: (x["arch"], x["shape"])):
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                         f"{r['error'][:40]} ||||||||")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.3f} | {r['peak_gib']:.2f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun_baseline.jsonl")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    print(markdown(args.inp, args.mesh))


if __name__ == "__main__":
    main()
