"""Filter-ladder benchmarks: bit-parallel myers GCUPS vs the wavefront
engine on the unit-cost kernels, plus ladder-on vs ladder-off mapping
throughput.

Three sections:

* **parity gate** (always, the ``--quick`` / tier-1 payload): the myers
  engine must be bit-exact — score *and* end cell — against the exact-DP
  engines on both unit-cost kernels across random length-mixed pairs
  (reference oracle at small buckets, wavefront at large ones, where the
  row-major oracle's compile time dominates);
* **GCUPS sweep** (full mode): batched ``edit_distance`` fill plans,
  myers vs wavefront, per bucket — lengths drawn from the
  ``(bucket/2, bucket]`` range bucketing guarantees, cells counted at
  the *actual* ``q_len * r_len``.  Asserts the >= 10x claim at buckets
  >= 256 after asserting bit-identity on the very blocks being timed;
* **ladder** (full mode): the mapper on a half-junk read stream
  (chimeric reads: a planted exact k-mer inside random sequence — they
  chain, then die in extension) with ``filter_mode='myers'`` vs
  ``'off'``.  The screen kills junk at bit-parallel cost before full DP
  runs; genuine-read accuracy must not move.
"""
from __future__ import annotations

import json
import time

import jax.numpy as jnp
import numpy as np

from repro.core import alphabets, kernels_zoo, reference
from repro.runtime import plan as plan_mod
from repro.runtime import registry

from .common import emit

GCUPS_FACTOR = 10.0            # acceptance floor at buckets >= 256
GCUPS_MIN_BUCKET = 256


def _mixed_batch(rng, n, bucket):
    qs = rng.integers(0, 4, (n, bucket)).astype(np.uint8)
    rs = rng.integers(0, 4, (n, bucket)).astype(np.uint8)
    ql = rng.integers(bucket // 2 + 1, bucket + 1, n).astype(np.int32)
    rl = rng.integers(bucket // 2 + 1, bucket + 1, n).astype(np.int32)
    return (jnp.asarray(qs), jnp.asarray(rs), jnp.asarray(ql),
            jnp.asarray(rl))


def _assert_same(a, b, ctx):
    for f in ("score", "end_i", "end_j"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{ctx}: {f}")


def parity_gate(rng, buckets, n: int = 8) -> int:
    """Assert myers == exact DP on both unit-cost kernels; #pairs checked.

    Unlimited mode must be bit-exact on score *and* end cell.  In
    thresholded mode the exact engines don't saturate, so the contract
    checked is ``where(exact > k, sentinel, exact)`` — with the end cell
    compared only where the distance survives the threshold.
    """
    checked = 0
    for kname in ("edit_distance", "edit_search"):
        for max_dist in (-1, 20):
            spec, _ = kernels_zoo.make(kname)
            params = {"max_dist": jnp.int32(max_dist)}
            sent = int(spec.sentinel())
            for bucket in buckets:
                batch = _mixed_batch(rng, n, bucket)
                ctx = f"{kname}/k{max_dist}/b{bucket}"
                my = plan_mod.get_plan(spec, "myers", (bucket,), (bucket,),
                                       batch_size=n, with_traceback=False,
                                       mode="fill")(params, *batch)
                if bucket <= 128:
                    qs, rs, ql, rl = batch
                    ex1 = [reference.run(spec, params, qs[i], rs[i],
                                         ql[i], rl[i]) for i in range(n)]
                    ex = {f: np.asarray([getattr(e, f) for e in ex1])
                          for f in ("score", "end_i", "end_j")}
                else:
                    ex0 = plan_mod.get_plan(
                        spec, "wavefront", (bucket,), (bucket,),
                        batch_size=n, with_traceback=False,
                        mode="fill")(params, *batch)
                    ex = {f: np.asarray(getattr(ex0, f))
                          for f in ("score", "end_i", "end_j")}
                want = ex["score"]
                if max_dist >= 0:        # the k-saturation contract
                    want = np.where(want > max_dist, sent, want)
                np.testing.assert_array_equal(np.asarray(my.score), want,
                                              err_msg=f"{ctx}: score")
                live = want < sent
                for f in ("end_i", "end_j"):
                    np.testing.assert_array_equal(
                        np.asarray(getattr(my, f))[live], ex[f][live],
                        err_msg=f"{ctx}: {f}")
                checked += n
    return checked


def _stream_time(plan, params, blocks, iters: int) -> float:
    import jax

    def once():
        outs = [plan(params, *b) for b in blocks]
        jax.block_until_ready(outs)

    once()                                 # warm / compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        once()
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def gcups_sweep(rng, buckets, n: int = 128, n_blocks: int = 4,
                iters: int = 5) -> list:
    # batch 128: the bit-parallel engine is dispatch-bound on CPU (its
    # per-op tensors are tiny), so GCUPS scales with batch width; the
    # wavefront engine is compute-bound and flat in batch.  Screens run
    # over bulk candidate batches, so the wide-batch number is the one
    # the ladder actually sees.
    spec, _ = kernels_zoo.make("edit_distance")
    params = {"max_dist": jnp.int32(-1)}
    cells_out = []
    for bucket in buckets:
        blocks = [_mixed_batch(rng, n, bucket) for _ in range(n_blocks)]
        cells = sum(int((np.asarray(ql).astype(np.int64) *
                         np.asarray(rl)).sum()) for _, _, ql, rl in blocks)
        my = plan_mod.get_plan(spec, "myers", (bucket,), (bucket,),
                               batch_size=n, with_traceback=False,
                               mode="fill")
        wf = plan_mod.get_plan(spec, "wavefront", (bucket,), (bucket,),
                               batch_size=n, with_traceback=False,
                               mode="fill")
        for blk in blocks:       # bit-identity on the timed blocks
            _assert_same(my(params, *blk), wf(params, *blk),
                         f"gcups/b{bucket}")
        t_my = _stream_time(my, params, blocks, iters)
        t_wf = _stream_time(wf, params, blocks, iters)
        cell = {"bucket": bucket, "batch": n,
                "gcups_myers": cells / t_my / 1e9,
                "gcups_wavefront": cells / t_wf / 1e9,
                "speedup": t_wf / t_my}
        cells_out.append(cell)
        emit(f"filter/gcups/b{bucket}/n{n}", t_my / (n * n_blocks),
             f"myers={cell['gcups_myers']:.3f} "
             f"wavefront={cell['gcups_wavefront']:.3f} "
             f"speedup={cell['speedup']:.1f}x")
        if bucket >= GCUPS_MIN_BUCKET:
            assert cell["speedup"] >= GCUPS_FACTOR, cell
    return cells_out


def junk_reads(rng, ref, n, read_len, plant_len: int = 20):
    """Chimeric junk: random sequence with one planted exact reference
    k-mer — it seeds and chains, then has no real placement."""
    out = []
    for _ in range(n):
        r = rng.integers(0, 4, read_len).astype(np.uint8)
        p = int(rng.integers(0, len(ref) - plant_len))
        o = int(rng.integers(0, read_len - plant_len))
        r[o:o + plant_len] = ref[p:p + plant_len]
        out.append(r)
    return out


def ladder_bench(rng, *, ref_len=16384, n_genuine=40, n_junk=40,
                 read_len=150) -> dict:
    from repro.data.synthetic import sample_reads
    from repro.mapping import ReadMapper

    ref = alphabets.random_dna(rng, ref_len)
    reads = sample_reads(ref, n_genuine, read_len, error_rate=0.05, seed=1)
    read_list = [np.asarray(reads.reads[i, : reads.lens[i]])
                 for i in range(n_genuine)]
    read_list += junk_reads(rng, ref, n_junk, read_len)
    n_total = len(read_list)

    out = {"n_genuine": n_genuine, "n_junk": n_junk, "ref_len": ref_len}
    for mode in ("myers", "off"):
        mapper = ReadMapper(ref, filter_mode=mode)
        mapper.map_reads(read_list)               # warm / compile
        t0 = time.perf_counter()
        recs = mapper.map_reads(read_list)
        dt = time.perf_counter() - t0
        acc = sum(1 for i in range(n_genuine)
                  if recs[i].is_mapped and
                  abs((recs[i].pos - 1) - int(reads.pos[i])) <= 5
                  ) / n_genuine
        junk_rejected = sum(1 for r in recs[n_genuine:]
                            if not r.is_mapped) / max(n_junk, 1)
        out[mode] = {"reads_per_s": n_total / dt, "accuracy": acc,
                     "junk_rejected": junk_rejected}
        emit(f"filter/ladder/{mode}", dt / n_total,
             f"reads_per_s={n_total / dt:.1f} acc={acc:.2f} "
             f"junk_rejected={junk_rejected:.2f}")
    out["ladder_speedup"] = (out["myers"]["reads_per_s"] /
                             out["off"]["reads_per_s"])
    assert out["myers"]["accuracy"] >= out["off"]["accuracy"], out
    return out


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    buckets = [64, 128] if quick else [64, 128, 256, 512]
    checked = parity_gate(rng, buckets)
    emit("filter/parity", 0.0, f"pairs={checked} buckets={buckets} ok")
    metrics: dict = {"parity_pairs": checked, "buckets": buckets}
    if quick:
        return metrics                # timing skipped: parity gate only
    metrics["cells"] = gcups_sweep(rng, buckets)
    metrics["ladder"] = ladder_bench(rng)
    info = plan_mod.plan_cache_info()
    metrics["plan_cache"] = {"size": info["size"], "hits": info["hits"],
                             "misses": info["misses"]}
    return metrics


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write headline metrics to OUT (JSON)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    metrics = run(quick=args.quick)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench_filter": metrics}, f, indent=2,
                      sort_keys=True)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
