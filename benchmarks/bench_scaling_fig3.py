"""Paper Fig. 3 analogue: throughput scaling in the two parallelism knobs.

* N_PE analogue — wavefront width: throughput vs sequence length (lanes =
  Q+1 PEs; saturation at the matrix edges mirrors Fig 3A's roll-off).
* N_B analogue — independent blocks: throughput vs vmap batch width
  (expected near-perfect scaling, Fig 3's N_B curves).
"""
from __future__ import annotations

import numpy as np

from repro.core import kernels_zoo
from .common import batched_plan, emit, kernel_batch, timeit


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    for kid, kname in [(1, "global_linear"), (9, "dtw")]:
        spec, params = kernels_zoo.make(kid)
        # N_B scaling (fixed 128x128 pairs)
        for nb in ([1, 4, 16] if quick else [1, 2, 4, 8, 16, 32]):
            qs, rs, ql, rl = kernel_batch(rng, spec, nb, 128, 128)
            fn = batched_plan(spec, nb, 128, 128, with_traceback=False)
            sec = timeit(fn, params, qs, rs, ql, rl)
            emit(f"fig3/{kname}/nb_{nb:02d}", sec,
                 f"aligns_per_s={nb / sec:.0f} "
                 f"cells_per_s={nb * 128 * 128 / sec:.3e}")
        # N_PE analogue: wavefront width via sequence length
        for sl in ([64, 256] if quick else [32, 64, 128, 256, 512]):
            qs, rs, ql, rl = kernel_batch(rng, spec, 4, sl, sl)
            fn = batched_plan(spec, 4, sl, sl, with_traceback=False)
            sec = timeit(fn, params, qs, rs, ql, rl)
            emit(f"fig3/{kname}/npe_{sl:03d}", sec,
                 f"cells_per_s={4 * sl * sl / sec:.3e}")


if __name__ == "__main__":
    run()
