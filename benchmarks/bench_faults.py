"""Chaos benchmark: the gateway's fault-tolerance contract under fire.

A mixed-length request stream drains through ``AlignmentService`` three
ways — a fault-free inline oracle, a fault-free 4-worker pool, and a
4-worker pool whose :class:`~repro.serve.FaultPlan` kills 2 workers
mid-stream — and the run *asserts* the robustness invariants rather than
just timing them:

* every submitted request completes (none lost, none hung);
* per-request results are bit-identical to the no-fault runs (recovery
  replays work, it never changes answers — batch composition does not
  leak into per-row results);
* zero double-completions (``stats['completed']`` equals the request
  count exactly: generation counters discarded every stale harvest);
* the kill schedule fired as planned and the stranded batches were
  reclaimed by the heartbeat deadline.

A fourth scenario injects seeded launch/harvest failures plus harvest
latency (``fail_launch_p``/``fail_harvest_p``/``latency_s``) and checks
the bounded-retry machinery converges to the same bit-identical results
without dead letters.

Headlines: ``recovery_s`` (kill detected -> stranded work requeued) and
``goodput_rps_faulty`` (completed requests per wall second with 2 of 4
workers dead).  Any invariant violation raises, which fails the
benchmark orchestrator (nonzero exit) — this is the chaos gate.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.serve import AlignmentService, FaultPlan

from .bench_serving import _clone, _stream
from .common import emit

KERNEL = "global_affine"
HEADLINES = {"goodput_rps_faulty": "higher"}


def _watch_recovery(svc, done: threading.Event) -> dict:
    """Poll stats for the kill -> redispatch timeline (the supervisor
    thread is busy running ``serve``); returns the shared dict."""
    seen: dict = {"t_kill": None, "t_recover": None}

    def loop():
        while not done.is_set():
            now = time.perf_counter()
            if seen["t_kill"] is None and svc.stats["killed"]:
                seen["t_kill"] = now
            if seen["t_kill"] is not None and seen["t_recover"] is None \
                    and svc.stats["redispatched"] > 0:
                seen["t_recover"] = now
                return
            time.sleep(0.002)

    threading.Thread(target=loop, daemon=True).start()
    return seen


def _check(reqs, res_ref, stats, label: str):
    unresolved = [r.rid for r in reqs if r.result is None]
    if unresolved:
        raise AssertionError(f"{label}: {len(unresolved)} requests never "
                             f"resolved (e.g. rid {unresolved[:5]})")
    failed = [r.rid for r in reqs if r.result.get("failed")]
    if failed:
        raise AssertionError(f"{label}: {len(failed)} requests dead-"
                             f"lettered (e.g. rid {failed[:5]})")
    if [r.result for r in reqs] != res_ref:
        diff = [r.rid for r, want in zip(reqs, res_ref)
                if r.result != want]
        raise AssertionError(f"{label}: results diverge from the no-fault "
                             f"run at rid {diff[:5]}")
    if stats["completed"] != len(reqs):
        raise AssertionError(
            f"{label}: completed {stats['completed']} != {len(reqs)} "
            f"submitted — lost or double-counted work")


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    n = 64 if quick else 256
    block = 2 if quick else 8
    lo, hi = 24, 128
    base = _stream(rng, n, lo, hi)

    def service(**kw):
        # coalesce off: a request's bucket (and so its padded shape) must
        # not depend on queue state, or bit-identity across schedules is
        # not even well-defined
        return AlignmentService(max_len=hi, block=block, coalesce=False,
                                pipeline_depth=2, **kw)

    # -- fault-free oracle (inline drain; also compiles every bucket) --------
    oracle = service()
    reqs = _clone(base)
    oracle.submit_all(reqs)
    oracle.drain()
    res_ref = [r.result for r in reqs]

    # -- fault-free 4-worker pool --------------------------------------------
    svc = service()
    reqs = _clone(base)
    svc.submit_all(reqs)
    t0 = time.perf_counter()
    stats = svc.serve(n_workers=4, timeout_s=600.0)
    wall_clean = time.perf_counter() - t0
    _check(reqs, res_ref, stats, "clean pool")

    # -- chaos: kill 2 of 4 workers mid-stream -------------------------------
    plan = FaultPlan(seed=0, kill={"w0": 1, "w1": 1})
    svc = service(fault_plan=plan, redispatch_after=0.75, max_retries=4)
    reqs = _clone(base)
    svc.submit_all(reqs)
    finished = threading.Event()
    seen = _watch_recovery(svc, finished)
    t0 = time.perf_counter()
    stats = svc.serve(n_workers=4, timeout_s=600.0)
    wall_faulty = time.perf_counter() - t0
    finished.set()
    _check(reqs, res_ref, stats, "chaos pool")
    killed = sorted(k["worker"] for k in stats["killed"])
    if killed != ["w0", "w1"]:
        raise AssertionError(f"kill schedule misfired: killed={killed}")
    if stats["redispatched"] < 1:
        raise AssertionError("no stranded batch was ever redispatched")
    if seen["t_kill"] is None or seen["t_recover"] is None:
        raise AssertionError("recovery watcher never saw kill+redispatch")
    recovery_s = seen["t_recover"] - seen["t_kill"]

    # -- flaky fabric: seeded launch/harvest failures + latency --------------
    plan = FaultPlan(seed=7, fail_launch_p=0.12, fail_harvest_p=0.08,
                     latency_s=0.02, latency_p=0.2)
    svc = service(fault_plan=plan, max_retries=8)
    reqs = _clone(base)
    svc.submit_all(reqs)
    t0 = time.perf_counter()
    fstats = svc.serve(n_workers=4, timeout_s=600.0)
    wall_flaky = time.perf_counter() - t0
    _check(reqs, res_ref, fstats, "flaky pool")
    if fstats["faults"] < 1 or fstats["retries"] < 1:
        raise AssertionError(
            f"fault plan never fired (faults={fstats['faults']}, "
            f"retries={fstats['retries']})")

    goodput_clean = n / wall_clean
    goodput_faulty = n / wall_faulty
    emit("faults/clean_pool", wall_clean / n,
         f"goodput_rps={goodput_clean:.1f}")
    emit("faults/kill_2_of_4", wall_faulty / n,
         f"goodput_rps={goodput_faulty:.1f} recovery_s={recovery_s:.3f} "
         f"redispatched={stats['redispatched']} identical=True")
    emit("faults/flaky_fabric", wall_flaky / n,
         f"faults={fstats['faults']} retries={fstats['retries']} "
         f"identical=True")
    return {
        "n_requests": n, "n_workers": 4, "n_killed": 2,
        "wall_s_clean": wall_clean, "wall_s_faulty": wall_faulty,
        "goodput_rps_clean": goodput_clean,
        "goodput_rps_faulty": goodput_faulty,
        "recovery_s": recovery_s,
        "redispatched": int(stats["redispatched"]),
        "dead_lettered": int(stats["dead_lettered"]),
        "flaky": {"wall_s": wall_flaky, "faults": int(fstats["faults"]),
                  "retries": int(fstats["retries"]),
                  "dead_lettered": int(fstats["dead_lettered"])},
        "identical": True,
    }


if __name__ == "__main__":
    run()
