"""Paper claim 5: long-read alignment via GACT-style tiling.

A 3 kb noisy PacBio-style read is aligned against its reference through a
fixed 128x128 device kernel with 48-cell overlap — the same heuristic the
paper demonstrates on AWS F1, driven host-side over the jitted kernel.

Run:  PYTHONPATH=src python examples/long_read_tiling.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import align, alphabets, kernels_zoo, rescore, tiling
from repro.core import types as T


def main():
    rng = np.random.default_rng(1)
    spec, params = kernels_zoo.make(2)          # Gotoh, like GACT
    ref = alphabets.random_dna(rng, 3000)
    read = alphabets.mutate(rng, ref, 0.12)
    q, r = jnp.asarray(read), jnp.asarray(ref)
    print(f"read {len(q)} bp vs reference {len(r)} bp (12% error)")

    t0 = time.perf_counter()
    tiled = tiling.tiled_align(spec, params, q, r, tile=128, overlap=48)
    dt = time.perf_counter() - t0
    a = T.Alignment(score=0, end_i=len(q), end_j=len(r), start_i=0,
                    start_j=0, moves=np.asarray(tiled.moves[::-1]),
                    n_moves=len(tiled.moves))
    tiled_score = rescore.rescore(spec, params, q, r, a)
    print(f"tiled:   {tiled.n_tiles} tiles, {dt:.1f}s, "
          f"score {tiled_score:.0f}")

    full = align(spec, params, q, r, with_traceback=False)
    print(f"full DP: score {float(full.score):.0f} "
          f"(tiled/full = {tiled_score / float(full.score):.4f})")
    assert tiled_score >= 0.98 * float(full.score)
    print("tiling preserves ≥98% of the DP optimum with O(tile) memory")


if __name__ == "__main__":
    main()
