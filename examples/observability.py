"""Watching the gateway work: span tracing + metrics on a faulty run.

Drives an ``AlignmentService`` under a deterministic FaultPlan (one
worker killed, flaky launches) with span tracing enabled, then reads
the three observability surfaces:

* ``svc.metrics()``   — counters/gauges/histograms, dead letters by
  kind, and the reconciliation invariant
  (submitted == resolved + dead-lettered);
* ``svc.dump_trace`` — a Chrome trace to open at
  https://ui.perfetto.dev (one track per gateway worker, a counter
  track for queue depth);
* ``svc.prometheus()`` — the same metrics as Prometheus text.

Run:  PYTHONPATH=src python examples/observability.py
"""
import numpy as np

from repro.core import alphabets
from repro.obs import trace
from repro.serve import AlignRequest, AlignmentService, FaultPlan

TRACE_PATH = "gateway_trace.json"


def main():
    rng = np.random.default_rng(0)

    trace.enable()                       # one global switch, off by default

    # chaos: kill worker w0 at its 2nd dispatch, fail 15% of launches
    plan = FaultPlan(seed=7, kill={"w0": 1}, fail_launch_p=0.15)
    svc = AlignmentService(max_len=128, block=4, fault_plan=plan,
                           redispatch_after=0.75, max_retries=2)
    for i in range(32):
        ref = alphabets.random_dna(rng, 120)
        read = alphabets.mutate(rng, ref, 0.1)[:128]
        svc.submit(AlignRequest(rid=i, kernel="global_affine",
                                query=read, ref=ref))
    svc.serve(n_workers=2, timeout_s=120.0, elastic=True, max_workers=4)

    m = svc.metrics()
    rec = m["reconcile"]
    print(f"reconcile: submitted={rec['submitted']} "
          f"resolved={rec['resolved']} "
          f"dead_lettered={rec['dead_lettered']} ok={rec['ok']}")
    print(f"dead letters by kind: {m['dead_letters_by_kind']}")
    for d in svc.dead_letters:
        print(f"  rid={d['rid']} kind={d['kind']} worker={d['worker']} "
              f"attempts={d['attempts']}")
    lat = m["metrics"]["histograms"].get("gw_latency_s{outcome=completed}")
    if lat:
        print(f"submit->resolve latency: p50={lat['p50'] * 1e3:.1f}ms "
              f"p95={lat['p95'] * 1e3:.1f}ms p99={lat['p99'] * 1e3:.1f}ms")
    print(f"plan cache: {m['plan_cache']}")

    obj = svc.dump_trace(TRACE_PATH)
    trace.disable()
    print(f"\nwrote {TRACE_PATH} ({len(obj['traceEvents'])} events) — "
          f"open it at https://ui.perfetto.dev")
    print("summarize it with: "
          f"python scripts/obs_report.py {TRACE_PATH}")

    print("\nPrometheus exposition (first lines):")
    for line in svc.prometheus().splitlines()[:8]:
        print(f"  {line}")


if __name__ == "__main__":
    main()
