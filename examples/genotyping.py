"""Genotyping quickstart: pair-HMM forward likelihoods -> genotype calls.

The probabilistic subsystem generalizes the DP engines over a semiring:
the same wavefront back-end that maximizes alignment scores accumulates
log-sum-exp path mass, turning it into the GATK-style pair-HMM forward
kernel.  This example simulates three variant sites (hom-ref, het,
hom-alt), computes every read x haplotype likelihood through the
batched runtime, calls genotypes with phred-scaled confidence, and runs
the same sites through the pipelined ``GenotypingService`` channel.

Run:  PYTHONPATH=src python examples/genotyping.py
"""
import time

import numpy as np

from repro.data.synthetic import sample_site
from repro.prob import call_site, forward_backward, default_params
from repro.runtime import plan as plan_mod
from repro.serve import GenotypeRequest, GenotypingService

GT_NAMES = {(0, 0): "0/0 hom-ref", (0, 1): "0/1 het", (1, 1): "1/1 hom-alt"}


def main():
    # -- direct pipeline: one site at a time --------------------------------
    print("# direct call_site:")
    sites = []
    for k, truth in enumerate([(0, 0), (0, 1), (1, 1)]):
        site = sample_site(seed=k, hap_len=96, read_len=48, n_reads=10,
                           genotype=truth, error_rate=0.02)
        sites.append(site)
        out = call_site(site.reads, site.haplotypes)
        status = "OK" if out["GT"] == truth else "WRONG"
        print(f"  site {k}: truth={GT_NAMES[truth]:>12}  "
              f"called={GT_NAMES[out['GT']]:>12}  GQ={out['GQ']:>2}  "
              f"PL={out['PL']}  [{status}]")

    # -- posterior decoding: where does read 0 sit on the ref allele? -------
    site = sites[1]
    post = forward_backward(default_params(), site.reads[0],
                            site.haplotypes[0])
    print(f"# posterior: logZ={post.log_z:.2f} "
          f"(backward check {post.log_z_backward:.2f}); "
          f"read 0 MAP path covers hap "
          f"[{post.map_path.min()}, {post.map_path.max()}]")

    # -- the serving channel: all sites through the pipelined dispatcher ----
    svc = GenotypingService(max_len=128, block=8, pipeline_depth=2,
                            max_pending=64, backpressure="block")
    futs = [svc.submit(GenotypeRequest(rid=k, reads=s.reads,
                                       haplotypes=s.haplotypes))
            for k, s in enumerate(sites)]
    t0 = time.perf_counter()
    svc.drain()
    dt = time.perf_counter() - t0
    calls = [f.result()["GT"] for f in futs]
    truths = [s.genotype for s in sites]
    print(f"# GenotypingService: {len(futs)} sites in {dt * 1e3:.0f} ms, "
          f"calls={calls}, all correct: {calls == truths}")

    sums = [k for k in plan_mod.plan_cache_info()["keys"]
            if k.semiring == "logsumexp"]
    print(f"# sum-semiring plans in the shared cache: {len(sums)}")
    assert calls == truths


if __name__ == "__main__":
    main()
