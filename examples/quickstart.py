"""Quickstart: define a brand-new DP kernel in ~20 lines and run it.

This is the paper's core productivity claim (kernels in days, not months):
the user writes only the PE recurrence + init + traceback FSM — the
wavefront back-end, banding, batching and traceback machinery are shared.

The kernel below is a *new* one, not in the zoo: global alignment with a
transition/transversion-aware substitution model (purines A<->G cheap,
pyrimidines C<->T cheap, cross expensive).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import DPKernelSpec, REGION_CORNER, STOP_ORIGIN, align
from repro.core.kernels_zoo import common as C
from repro.core.traceback import moves_to_cigar
from repro.core import alphabets


def titv_sub(params, q, r):
    """Transition (A<->G, C<->T) scores milder than transversion."""
    is_transition = (q // 2 == r // 2) & (q != r)
    return jnp.where(q == r, params["match"],
                     jnp.where(is_transition, params["transition"],
                               params["transversion"]))


spec = DPKernelSpec(
    name="titv_global", n_layers=1,
    pe=C.linear_pe(titv_sub),
    init_row=C.linear_gap_init, init_col=C.linear_gap_init,
    region=REGION_CORNER,
    traceback=C.linear_tb(STOP_ORIGIN),
)
params = {"match": jnp.int32(2), "transition": jnp.int32(-1),
          "transversion": jnp.int32(-4), "gap": jnp.int32(-2)}


def main():
    rng = np.random.default_rng(0)
    ref = alphabets.random_dna(rng, 80)
    read = alphabets.mutate(rng, ref, 0.15)
    q, r = jnp.asarray(read), jnp.asarray(ref)

    # Three engines, one spec: oracle, optimized wavefront, Pallas (TPU
    # kernel, validated here in interpret mode).
    for engine in ["reference", "wavefront", "pallas_interpret"]:
        a = align(spec, params, q, r, engine_name=engine)
        print(f"{engine:18s} score={int(a.score):4d} "
              f"cigar={moves_to_cigar(a.moves, a.n_moves)[:40]}...")
    print("\nNew kernel defined in ~20 lines; all back-ends agree.")


if __name__ == "__main__":
    main()
