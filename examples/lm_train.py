"""End-to-end training driver: a ~100M-class LM, a few hundred steps.

Everything is the production stack scaled to one host: the same config
system, logical-axis sharding (trivially resolved on 1 device), AdamW,
cosine schedule, atomic checkpointing with resume, and the synthetic
Markov token pipeline (cross-entropy falls well below the unigram floor).

Run:   PYTHONPATH=src python examples/lm_train.py            # ~100M, 300 steps
Quick: PYTHONPATH=src python examples/lm_train.py --preset small --steps 60
"""
import argparse

from repro.configs.base import ModelConfig
from repro.launch.train import train_loop
from repro.optim import AdamWConfig

PRESETS = {
    # ~110M params (GPT-2-small class): the assignment's e2e target.
    "100m": ModelConfig(
        name="example-lm-100m", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=3072, vocab_size=8192,
        norm="rmsnorm", act="swiglu", positional="rope",
        tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32", remat=False),
    # ~22M: same shape family, minutes on this CPU container.
    "small": ModelConfig(
        name="example-lm-22m", family="dense",
        n_layers=6, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
        d_ff=1536, vocab_size=8192,
        norm="rmsnorm", act="swiglu", positional="rope",
        tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32", remat=False),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="100m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_train")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    from repro.launch.roofline import param_count
    print(f"model: {cfg.name} (~{param_count(cfg) / 1e6:.0f}M non-embed "
          f"params), {args.steps} steps @ batch {args.batch} x seq "
          f"{args.seq}")
    losses = []
    state, metrics = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq, lr=args.lr,
        ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=10,
        opt_cfg=AdamWConfig(weight_decay=0.01),
        on_metrics=lambda s, m: losses.append(float(m["loss"])))
    if len(losses) >= 2:
        print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"({'FELL' if losses[-1] < losses[0] - 0.1 else 'check run'})")


if __name__ == "__main__":
    main()
