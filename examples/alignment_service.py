"""The paper's accelerator as a production service.

Heterogeneous kernel channels (the paper's N_K: a global aligner, a local
aligner, and a DTW basecalling channel run side by side), block batching
(N_B), deadline-based straggler re-dispatch, and CIGAR outputs.

Run:  PYTHONPATH=src python examples/alignment_service.py
"""
import numpy as np

from repro.core import alphabets
from repro.serve import (AlignFuture, AlignRequest, AlignmentService,
                         InflightBatch)


def main():
    rng = np.random.default_rng(0)
    svc = AlignmentService(max_len=160, block=8)

    # channel 1: whole-read global affine alignments
    for i in range(12):
        ref = alphabets.random_dna(rng, 150)
        read = alphabets.mutate(rng, ref, 0.12)[:160]
        svc.submit(AlignRequest(rid=i, kernel="global_affine",
                                query=read, ref=ref))
    # channel 2: motif search via local alignment
    for i in range(12, 18):
        hay = alphabets.random_dna(rng, 150)
        needle = hay[40:90]
        svc.submit(AlignRequest(rid=i, kernel="local_linear",
                                query=needle, ref=hay))
    # channel 3: squiggle matching (sDTW, score-only)
    for i in range(18, 22):
        sig = rng.integers(0, 128, 120).astype(np.int32)
        svc.submit(AlignRequest(rid=i, kernel="sdtw",
                                query=sig[10:90], ref=sig))

    n = svc.drain()
    print(f"drained {n} requests over {len(svc.channels)} kernel channels\n")
    for kernel, (spec, _, _) in svc.channels.items():
        print(f"channel {kernel!r}: traceback="
              f"{'yes' if spec.traceback else 'no'}")

    # a worker dies mid-batch -> its work is re-queued by deadline; the
    # requeued copy gets a new generation, so the dead worker's late
    # result (if it ever lands) is discarded rather than double-completing
    late = AlignRequest(rid=99, kernel="global_affine",
                        query=alphabets.random_dna(rng, 50),
                        ref=alphabets.random_dna(rng, 50))
    fut = AlignFuture(late, svc)
    svc.inflight["w9"] = [InflightBatch(        # launched, never harvested
        worker="w9", kernel=late.kernel, bucket=(64, 64),
        reqs=[late], gens=[late.gen], out=None)]
    requeued = svc.redispatch_dead()            # w9 never beat -> dead
    print(f"\nstraggler handling: {requeued} request(s) re-queued after "
          f"worker death; drained again -> {svc.drain()} done; "
          f"future resolved: {fut.done()}")


if __name__ == "__main__":
    main()
