"""Read mapping quickstart: simulate reads, map them, print SAM.

The mapping subsystem turns the kernel zoo into a pipeline: minimizer
index -> batched seeding -> sparse anchor chaining (a 1-D DP kernel) ->
banded semiglobal extension through the shared CompiledPlan cache -> SAM
records.  This example simulates error-carrying reads from a random
reference (both strands), maps them back, and checks that >= 95% land
within 5 bp of their true origin with CIGARs that consume the full read.

Run:  PYTHONPATH=src python examples/read_mapping.py
"""
import time

import numpy as np

from repro.core import alphabets
from repro.data.synthetic import sample_reads
from repro.mapping import ReadMapper, cigar_spans
from repro.runtime import plan as plan_mod


def main():
    rng = np.random.default_rng(0)
    ref = alphabets.random_dna(rng, 20000)
    reads = sample_reads(ref, n=60, length=200, error_rate=0.08, seed=1)

    mapper = ReadMapper(ref, rname="synthetic_20k")
    t0 = time.perf_counter()
    records = mapper.map_reads(reads.reads, reads.lens)
    elapsed = time.perf_counter() - t0

    hits = cigars_ok = 0
    for i, rec in enumerate(records):
        if rec.is_mapped and abs((rec.pos - 1) - int(reads.pos[i])) <= 5:
            hits += 1
            if cigar_spans(rec.cigar)[0] == int(reads.lens[i]):
                cigars_ok += 1
    acc = hits / len(records)

    print("# first records:")
    for rec in records[:5]:
        line = rec.to_line()
        print(line[:100] + ("..." if len(line) > 100 else ""))
    info = plan_mod.plan_cache_info()
    print(f"\nmapped {hits}/{len(records)} within +-5 bp "
          f"(accuracy {acc:.2f}), {cigars_ok} full-span CIGARs, "
          f"{elapsed:.2f}s ({len(records) / elapsed:.1f} reads/s)")
    print(f"plan cache: {info['size']} compiled shapes, "
          f"{info['hits']} hits")
    assert acc >= 0.95, f"mapping accuracy {acc:.2f} below 0.95"
    assert cigars_ok == hits, "some CIGARs do not consume the full read"
    print("read mapping OK")


if __name__ == "__main__":
    main()
