#!/usr/bin/env python
"""Seeded chaos sweep over the serving gateway.

For each seed, a mixed-length alignment workload drains twice: once
fault-free (inline oracle) and once through a multi-worker ``serve()``
pool under a :class:`~repro.serve.FaultPlan` that kills the first
``--kill`` workers at their second dispatch and fails launches/harvests
with the seeded probabilities.  The sweep then asserts the gateway's
fault-tolerance invariants:

* every submitted request resolves — with a result bit-identical to the
  fault-free run, or a *typed* dead-letter error after bounded retries;
* zero double-completions (completed + dead-lettered == submitted);
* the kill schedule fired and stranded batches were redispatched.

Any violation is reported and the exit code is nonzero — this is the
scriptable face of the ``bench_faults`` chaos gate, cheap enough for
tier-1 (see scripts/tier1.sh) and sweepable over many seeds locally.

Examples:
    python scripts/chaos.py                       # 3-seed default sweep
    python scripts/chaos.py --seeds 0 7 42 --requests 128 --workers 6
    python scripts/chaos.py --fail-launch-p 0.3 --max-retries 2  # letters
    python scripts/chaos.py --json chaos_report.json
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

KNOWN_KINDS = {"deadline", "retries", "shed", "injected", "killed",
               "timeout", "error"}


def build_stream(np, AlignRequest, seed, n, lo, hi):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        lq = min(hi, lo + int(rng.exponential(scale=(hi - lo) / 3.0)))
        lr = min(hi, lo + int(rng.exponential(scale=(hi - lo) / 3.0)))
        reqs.append(AlignRequest(
            rid=i, kernel="global_affine",
            query=rng.integers(0, 4, lq).astype(np.uint8),
            ref=rng.integers(0, 4, lr).astype(np.uint8)))
    return reqs


def run_seed(seed, args):
    import numpy as np

    from repro.serve import (AlignRequest, AlignmentService, FaultPlan,
                             GatewayTimeout)

    violations = []

    def service(**kw):
        return AlignmentService(
            max_len=args.max_len, block=args.block, coalesce=False,
            pipeline_depth=2, **kw)

    base = build_stream(np, AlignRequest, seed, args.requests, 24,
                        args.max_len)

    def clone():
        return [AlignRequest(rid=r.rid, kernel=r.kernel, query=r.query,
                             ref=r.ref) for r in base]

    oracle = service()
    ref = clone()
    oracle.submit_all(ref)
    oracle.drain()

    plan = FaultPlan(
        seed=seed,
        kill={f"w{i}": 1 for i in range(args.kill)},
        fail_launch_p=args.fail_launch_p,
        fail_harvest_p=args.fail_harvest_p,
        latency_s=args.latency_s, latency_p=args.latency_p)
    svc = service(fault_plan=plan, redispatch_after=0.75,
                  max_retries=args.max_retries)
    reqs = clone()
    svc.submit_all(reqs)
    t0 = time.perf_counter()
    try:
        stats = svc.serve(n_workers=args.workers, timeout_s=args.timeout_s)
    except GatewayTimeout as exc:
        violations.append(f"serve() timed out: {exc}")
        stats = dict(svc.stats)
    wall_s = time.perf_counter() - t0

    dead_rids = {d["rid"] for d in svc.dead_letters}
    completed = mismatched = lettered = 0
    for r, want in zip(reqs, ref):
        if r.result is None:
            violations.append(f"rid {r.rid}: never resolved")
        elif r.result.get("failed"):
            lettered += 1
            kind = r.result["error"].get("kind")
            if kind not in KNOWN_KINDS:
                violations.append(f"rid {r.rid}: untyped failure {kind!r}")
            if r.rid not in dead_rids:
                violations.append(
                    f"rid {r.rid}: failed result without a dead-letter "
                    f"record")
        else:
            completed += 1
            if r.result != want.result:
                mismatched += 1
    if mismatched:
        violations.append(
            f"{mismatched} completed results diverge from the fault-free "
            f"run (recovery must never change answers)")
    if stats["completed"] + lettered != args.requests:
        violations.append(
            f"completed {stats['completed']} + dead-lettered {lettered} "
            f"!= {args.requests} submitted (lost or double-counted work)")
    killed = sorted(k["worker"] for k in stats["killed"])
    if args.kill and killed != [f"w{i}" for i in range(args.kill)]:
        violations.append(f"kill schedule misfired: killed={killed}")
    if args.kill and stats["redispatched"] < 1:
        violations.append("no stranded batch was ever redispatched")

    # the metrics snapshot must reconcile exactly with what this script
    # counted off the futures — same invariant, independent bookkeeping
    m = svc.metrics()
    rec = m["reconcile"]
    if not rec["ok"]:
        violations.append(f"metrics snapshot does not reconcile: {rec}")
    if rec["dead_lettered"] != lettered:
        violations.append(
            f"metrics count {rec['dead_lettered']} dead letters; "
            f"futures show {lettered}")
    counters = m["metrics"]["counters"]
    for kind, k_n in m["dead_letters_by_kind"].items():
        got = int(counters.get(f"gw_dead_letters_total{{kind={kind}}}", 0))
        if got != k_n:
            violations.append(
                f"dead-letter metric kind={kind}: {got} != {k_n} records")
    if int(counters.get("gw_retries_total", 0)) != int(stats["retries"]):
        violations.append(
            f"retry metric {counters.get('gw_retries_total')} != "
            f"stats {stats['retries']}")

    return {
        "seed": seed, "wall_s": round(wall_s, 3),
        "completed": completed, "dead_lettered": lettered,
        "identical": mismatched == 0,
        "killed": killed,
        "redispatched": int(stats["redispatched"]),
        "retries": int(stats["retries"]),
        "faults": int(stats["faults"]),
        "dead_letters": [dict(d) for d in svc.dead_letters],
        "dead_letters_by_kind": m["dead_letters_by_kind"],
        "reconcile": rec,
        "violations": violations,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--seeds", nargs="+", type=int, default=[0, 1, 2],
                    help="fault-plan + workload seeds (default: 0 1 2)")
    ap.add_argument("--requests", type=int, default=48,
                    help="requests per seed (default 48)")
    ap.add_argument("--workers", type=int, default=4,
                    help="dispatcher pool size (default 4)")
    ap.add_argument("--kill", type=int, default=2,
                    help="workers killed at their 2nd dispatch (default 2)")
    ap.add_argument("--fail-launch-p", type=float, default=0.1)
    ap.add_argument("--fail-harvest-p", type=float, default=0.05)
    ap.add_argument("--latency-s", type=float, default=0.0)
    ap.add_argument("--latency-p", type=float, default=0.0)
    ap.add_argument("--max-retries", type=int, default=6)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--block", type=int, default=2,
                    help="batch rows per dispatch (small = many batches)")
    ap.add_argument("--timeout-s", type=float, default=300.0)
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write the sweep report to OUT")
    ap.add_argument("--trace", default=None, metavar="OUT",
                    help="record spans for the faulty runs and write a "
                         "Perfetto-loadable Chrome trace to OUT")
    args = ap.parse_args(argv)
    if args.kill > args.workers:
        ap.error(f"--kill {args.kill} > --workers {args.workers}")

    if args.trace:
        from repro.obs import trace as obs_trace
        obs_trace.enable()

    reports = []
    for seed in args.seeds:
        rep = run_seed(seed, args)
        reports.append(rep)
        status = "ok" if not rep["violations"] else "FAIL"
        print(f"chaos seed={seed}: {status} completed={rep['completed']} "
              f"dead_lettered={rep['dead_lettered']} "
              f"killed={len(rep['killed'])} "
              f"redispatched={rep['redispatched']} "
              f"retries={rep['retries']} wall_s={rep['wall_s']}",
              flush=True)
        shown = rep["dead_letters"][:20]
        for d in shown:
            print(f"  dead-letter rid={d['rid']} kind={d['kind']} "
                  f"worker={d['worker']} attempts={d['attempts']} "
                  f"ts={d['ts']:.3f}", flush=True)
        if len(rep["dead_letters"]) > len(shown):
            print(f"  ... and {len(rep['dead_letters']) - len(shown)} "
                  f"more dead letters", flush=True)
        for v in rep["violations"]:
            print(f"  VIOLATION: {v}", flush=True)

    violations = [v for rep in reports for v in rep["violations"]]
    out = {"config": {k: v for k, v in vars(args).items()
                      if k not in ("json", "trace")},
           "seeds": reports, "ok": not violations}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}", flush=True)
    if args.trace:
        from repro.obs import export as obs_export
        obj = obs_export.write_chrome_trace(args.trace)
        obs_trace.disable()
        errs = obs_export.validate_chrome_trace(obj)
        if errs:
            violations.extend(f"trace: {e}" for e in errs)
        print(f"wrote {args.trace} ({len(obj['traceEvents'])} events, "
              f"{'INVALID' if errs else 'valid'})", flush=True)
    if violations:
        print(f"chaos sweep: {len(violations)} invariant violation(s)",
              flush=True)
        return 1
    print(f"chaos sweep: all invariants held across "
          f"{len(args.seeds)} seed(s)", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
