#!/usr/bin/env python
"""Lint the registered kernel×engine plan space without compiling it.

Sweeps every (kernel, engine) pair the registry admits at a
representative bucket/batch through the trace-time rules in
``repro.analyze`` and exits nonzero iff any error-severity finding
survives.  Wired into tier-1 (scripts/tier1.sh) and CI.

Examples:
    python scripts/lint_plans.py                      # full sweep, text
    python scripts/lint_plans.py --json               # machine-readable
    python scripts/lint_plans.py --rules R3 R401      # one family + one rule
    python scripts/lint_plans.py --ignore R303        # drop HLO scan
    python scripts/lint_plans.py --kernels 11 12 --engines banded \\
        --bucket 48x64 --batch 8
    python scripts/lint_plans.py --list-rules
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def parse_bucket(text):
    try:
        q, r = text.lower().split("x")
        return int(q), int(r)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"bucket must look like 64x64, got {text!r}")


def main(argv=None):
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--kernels", nargs="+", default=None,
                    help="kernel ids or names (default: whole zoo)")
    ap.add_argument("--engines", nargs="+", default=None,
                    help="engine names (default: all registered)")
    ap.add_argument("--bucket", type=parse_bucket, default=(64, 64),
                    metavar="QxR", help="bucket shape (default 64x64)")
    ap.add_argument("--batch", type=int, default=4,
                    help="batch size; 0 means single-pair plans")
    ap.add_argument("--rules", nargs="+", default=None, metavar="ID",
                    help="only these rule IDs/prefixes (e.g. R3 R401)")
    ap.add_argument("--ignore", nargs="+", default=None, metavar="ID",
                    help="drop these rule IDs/prefixes")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip HLO-lowering rules (faster; R303 off)")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON")
    ap.add_argument("--verbose", "-v", action="store_true",
                    help="include info-severity findings in text output")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    from repro import analyze

    if args.list_rules:
        for rule in analyze.ALL_RULES:
            print(f"{rule.id}  {rule.severity:7s} {rule.scope:6s} "
                  f"{rule.title:14s} {rule.doc}")
        return 0

    kernels = None
    if args.kernels is not None:
        kernels = [int(k) if k.isdigit() else k for k in args.kernels]

    config = analyze.LintConfig(hlo_rules=not args.no_hlo)
    try:
        report = analyze.lint_all(
            kernels=kernels, engines=args.engines, bucket=args.bucket,
            batch_size=args.batch or None, rules=args.rules,
            ignore=args.ignore, config=config)
    except ValueError as e:                      # bad selector / kernel name
        print(f"lint_plans: {e}", file=sys.stderr)
        return 2

    if args.json:
        print(report.to_json())
    else:
        print(report.format_text(verbose=args.verbose))
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
