#!/usr/bin/env python
"""Sweep the plan design space and commit the winners to a tuning table.

    PYTHONPATH=src python scripts/autotune.py \
        --kernels global_linear,global_affine --engines wavefront \
        --buckets 64,128,256 --batches 8 --out TUNE_TABLE.json

Each (kernel, engine, bucket, batch) point enumerates the engine's legal
schedule grid, prunes it with the lowered-HLO roofline, compiles and
times the survivors (parity-gated against the hand-picked default), and
records the measured winner.  The written table is consulted by
``runtime.plan.get_plan`` whenever a caller passes no explicit schedule
option; ``REPRO_TUNE_TABLE=off`` disables it.

Entries are keyed by backend and JAX version, so re-running after an
upgrade refreshes rather than poisons: stale entries simply stop
matching.  ``--merge`` starts from an existing table (default when
``--out`` exists) so sweeps can be grown incrementally.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser(
        description="autotune plan schedules into a persisted table")
    ap.add_argument("--kernels", default="global_linear,global_affine",
                    help="comma-separated kernels_zoo names")
    ap.add_argument("--engines", default="wavefront",
                    help="comma-separated engine names")
    ap.add_argument("--buckets", default="64,128,256",
                    help="comma-separated square bucket lengths")
    ap.add_argument("--batches", default="8",
                    help="comma-separated batch sizes ('single' = "
                         "un-batched plan)")
    ap.add_argument("--out", default=None,
                    help="table path (default: repo-root TUNE_TABLE.json)")
    ap.add_argument("--top-k", type=int, default=4,
                    help="candidates the cost model keeps per point")
    ap.add_argument("--iters", type=int, default=3,
                    help="timing repeats per candidate (median)")
    ap.add_argument("--fresh", action="store_true",
                    help="ignore an existing table instead of merging")
    args = ap.parse_args()

    # the sweep must measure against the hand-picked defaults, never an
    # already-installed table
    os.environ["REPRO_TUNE_TABLE"] = "off"

    from repro import tune

    out = args.out or str(tune.default_path())
    table = None
    if not args.fresh and os.path.isfile(out):
        table = tune.TuningTable.load(out)
        print(f"# merging into {out} ({len(table)} entries)")

    def parse_batch(tok: str):
        return None if tok.strip() == "single" else int(tok)

    points = [(k.strip(), e.strip(), (int(b), int(b)), parse_batch(n))
              for k in args.kernels.split(",")
              for e in args.engines.split(",")
              for b in args.buckets.split(",")
              for n in args.batches.split(",")]
    print(f"# sweeping {len(points)} points "
          f"(top_k={args.top_k}, iters={args.iters})")
    table = tune.run_sweep(points, table=table, top_k=args.top_k,
                           iters=args.iters, log=lambda m: print(f"# {m}"))
    table.save(out)
    print(f"# wrote {out} ({len(table)} entries)")

    from repro.runtime import plan as plan_mod
    totals = plan_mod.plan_cache_info()["totals"]
    print(f"# compiled {totals['compiled']} plans, "
          f"{totals['compile_s']:.1f}s total compile time")


if __name__ == "__main__":
    main()
