#!/usr/bin/env python
"""Postmortem report over a gateway Chrome trace (Gateway.dump_trace).

Reads a trace-event JSON file, validates it against the schema, and
prints the attribution a chaos-run postmortem needs without opening
Perfetto: per-stage time breakdown (where did the cycles go), per-track
busy time with launch+harvest coverage (is the dispatcher burning host
time off the books), instant-event tallies (retries, dead letters,
kills, respawns), and the top-N slowest spans.

Invariants are checked and any violation makes the exit code nonzero:

* the file must validate against the trace-event schema;
* every worker track's launch+harvest spans must cover >= --min-coverage
  (default 0.90) of its gateway busy time — "harvest time unaccounted"
  means the span instrumentation has a hole.  Stub tracks (a worker
  killed at its first dispatch, an idle poller) carry milliseconds of
  formation time and no launches, so the floor only applies to tracks
  with at least 5% of the busiest worker's gateway time;
* complete events must not overlap on one track (spans on a single
  thread are sequential by construction; overlap means clock misuse).

Examples:
    python scripts/obs_report.py gateway_trace.json
    python scripts/obs_report.py trace.json --top 20 --json report.json
"""
import argparse
import collections
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs import export as obs_export  # noqa: E402

# instants that mark gateway lifecycle events, tallied separately
EVENT_NAMES = ("gw.retry", "gw.dead_letter", "gw.kill", "gw.respawn",
               "gw.degrade")


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def analyze(obj: dict, top: int = 10, min_coverage: float = 0.90) -> dict:
    violations = list(obs_export.validate_chrome_trace(obj))
    events = obj.get("traceEvents", []) if isinstance(obj, dict) else []

    track_names = {}
    for ev in events:
        if isinstance(ev, dict) and ev.get("ph") == "M" \
                and ev.get("name") == "thread_name":
            track_names[ev.get("tid")] = ev["args"]["name"]

    spans = [ev for ev in events if isinstance(ev, dict)
             and ev.get("ph") == "X"
             and isinstance(ev.get("dur"), (int, float))]
    instants = [ev for ev in events if isinstance(ev, dict)
                and ev.get("ph") == "i"]

    # -- per-stage breakdown ------------------------------------------------
    by_stage: dict = collections.defaultdict(
        lambda: {"count": 0, "total_us": 0.0, "max_us": 0.0})
    for ev in spans:
        st = by_stage[ev["name"]]
        st["count"] += 1
        st["total_us"] += ev["dur"]
        st["max_us"] = max(st["max_us"], ev["dur"])
    total_us = sum(st["total_us"] for st in by_stage.values())
    for st in by_stage.values():
        st["frac"] = st["total_us"] / total_us if total_us else 0.0

    # -- per-track busy + coverage + overlap --------------------------------
    tracks: dict = {}
    for ev in spans:
        name = track_names.get(ev.get("tid"), f"tid{ev.get('tid')}")
        t = tracks.setdefault(name, {"busy_us": 0.0, "covered_us": 0.0,
                                     "spans": []})
        if ev.get("cat") == "gateway":
            t["busy_us"] += ev["dur"]
            if ev["name"] in ("gw.launch", "gw.harvest"):
                t["covered_us"] += ev["dur"]
        t["spans"].append((ev["ts"], ev["ts"] + ev["dur"], ev["name"]))
    max_busy = max((t["busy_us"] for n, t in tracks.items()
                    if n.startswith("gw-")), default=0.0)
    for name, t in tracks.items():
        t["coverage"] = (t["covered_us"] / t["busy_us"]
                         if t["busy_us"] else None)
        spans_sorted = sorted(t.pop("spans"))
        # nested child spans (dispatch.* around gw.*) are legitimate;
        # only *partial* overlap between siblings is a clock violation
        stack = []
        for s0, s1, nm in spans_sorted:
            while stack and stack[-1][1] <= s0:
                stack.pop()
            if stack and s1 > stack[-1][1]:
                violations.append(
                    f"track {name}: span {nm!r} at {s0:.0f}us partially "
                    f"overlaps {stack[-1][2]!r} (monotonic-clock misuse)")
                break
            stack.append((s0, s1, nm))
        t["stub"] = t["busy_us"] < 0.05 * max_busy
        if name.startswith("gw-") and not t["stub"] \
                and t["coverage"] is not None \
                and t["coverage"] < min_coverage:
            violations.append(
                f"track {name}: launch+harvest cover only "
                f"{t['coverage']:.1%} of gateway busy time "
                f"(floor {min_coverage:.0%}) — harvest time unaccounted")

    # -- instant-event tallies ----------------------------------------------
    event_counts = collections.Counter(
        ev["name"] for ev in instants if ev.get("name") in EVENT_NAMES)

    slowest = sorted(spans, key=lambda ev: -ev["dur"])[:top]
    return {
        "n_events": len(events),
        "n_spans": len(spans),
        "total_span_us": total_us,
        "stages": {k: dict(v) for k, v in sorted(
            by_stage.items(), key=lambda kv: -kv[1]["total_us"])},
        "tracks": tracks,
        "events": dict(event_counts),
        "slowest": [{"name": ev["name"], "dur_us": ev["dur"],
                     "ts_us": ev["ts"],
                     "track": track_names.get(ev.get("tid"),
                                              f"tid{ev.get('tid')}"),
                     "args": ev.get("args", {})} for ev in slowest],
        "violations": violations,
    }


def print_report(rep: dict) -> None:
    print(f"trace: {rep['n_events']} events, {rep['n_spans']} spans, "
          f"{rep['total_span_us'] / 1e3:.1f} ms total span time")
    print("\nper-stage breakdown:")
    print(f"  {'stage':<22}{'count':>7}{'total ms':>11}"
          f"{'max ms':>9}{'share':>8}")
    for name, st in rep["stages"].items():
        print(f"  {name:<22}{st['count']:>7}"
              f"{st['total_us'] / 1e3:>11.2f}"
              f"{st['max_us'] / 1e3:>9.2f}{st['frac']:>8.1%}")
    print("\nper-track busy time:")
    for name, t in sorted(rep["tracks"].items()):
        cov = ("n/a" if t["coverage"] is None
               else f"{t['coverage']:.1%}")
        tag = "  (stub: not gated)" if t.get("stub") else ""
        print(f"  {name:<22}busy={t['busy_us'] / 1e3:>9.2f} ms  "
              f"launch+harvest coverage={cov}{tag}")
    if rep["events"]:
        print("\nlifecycle events: " + ", ".join(
            f"{k}={v}" for k, v in sorted(rep["events"].items())))
    print(f"\ntop {len(rep['slowest'])} slowest spans:")
    for s in rep["slowest"]:
        print(f"  {s['dur_us'] / 1e3:>9.2f} ms  {s['name']:<18} "
              f"on {s['track']}  args={s['args']}")
    for v in rep["violations"]:
        print(f"VIOLATION: {v}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("trace", help="Chrome trace-event JSON "
                                  "(Gateway.dump_trace output)")
    ap.add_argument("--top", type=int, default=10,
                    help="slowest spans to list (default 10)")
    ap.add_argument("--min-coverage", type=float, default=0.90,
                    help="launch+harvest floor on worker tracks "
                         "(default 0.90)")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write the report as JSON to OUT")
    args = ap.parse_args(argv)

    rep = analyze(load(args.trace), top=args.top,
                  min_coverage=args.min_coverage)
    print_report(rep)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rep, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    if rep["violations"]:
        print(f"obs report: {len(rep['violations'])} invariant "
              f"violation(s)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
