#!/usr/bin/env bash
# Tier-1 verification: the full test suite plus the bucketing benchmark.
# One entry point for builders and CI; run from the repo root.
#
#   scripts/tier1.sh            # everything (slow model/serve suites too)
#   scripts/tier1.sh -m 'not slow'   # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"
python -m benchmarks.run --quick --only bucketing
python -m benchmarks.run --quick --only mapping
python -m benchmarks.run --quick --only serving
python -m benchmarks.run --quick --only fill   # packed/strip parity gate
python -m benchmarks.run --quick --only pairhmm  # forward-oracle parity gate
python -m benchmarks.run --quick --only filter   # myers bit-exactness gate
python -m benchmarks.run --quick --only autotune # table round-trip + parity gate
