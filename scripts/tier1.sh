#!/usr/bin/env bash
# Tier-1 verification: the full test suite, the quick benchmark gates,
# and the plan linter.  One entry point for builders and CI; run from
# the repo root.
#
#   scripts/tier1.sh            # everything (slow model/serve suites too)
#   scripts/tier1.sh --quick    # deselect the multi-minute slow suites
#   scripts/tier1.sh -m 'not slow'   # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--quick" ]]; then
    shift
    set -- -m 'not slow' "$@"
fi

python -m pytest -x -q "$@"
python -m benchmarks.run --quick --only bucketing
python -m benchmarks.run --quick --only mapping
python -m benchmarks.run --quick --only serving
python -m benchmarks.run --quick --only fill   # packed/strip parity gate
python -m benchmarks.run --quick --only pairhmm  # forward-oracle parity gate
python -m benchmarks.run --quick --only filter   # myers bit-exactness gate
python -m benchmarks.run --quick --only autotune # table round-trip + parity gate
python -m benchmarks.run --quick --only bench_obs # tracing overhead + reconcile gate
python scripts/lint_plans.py                     # trace-time plan lint gate
python scripts/chaos.py --seeds 0 --requests 32  # gateway fault-tolerance gate
